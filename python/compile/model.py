"""L2: SAC / TD3 forward+backward over a single flat parameter vector.

Everything here is a pure function of flat f32 vectors so the Rust runtime
(which only speaks buffers) can drive it: ``full_step`` consumes
(params, targets, adam m/v, step, batch, noise, hyper) and returns the new
state plus a metrics vector. ``actor_step``/``critic_step`` split the same
computation along the paper's Fig. 3 device boundary for the dual-"GPU"
Actor-Critic model parallelism.

MLP layers call the L1 Pallas ``fused_linear`` kernel (with its Pallas
backward), optimizer/targets use the fused ``adam_update``/``polyak``
kernels, and the inference head uses the ``gaussian_head`` kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layout import Layout
from .kernels import ref
from .kernels.fused_linear import fused_linear
from .kernels.elementwise import adam_update, polyak
from .kernels.gaussian_head import gaussian_head

# hyper vector layout (runtime-tunable scalars, shared by all artifacts)
HYPER = ("lr", "gamma", "tau", "target_entropy", "reward_scale", "policy_noise")
N_HYPER = len(HYPER)

# metrics vector layout (what the Rust metrics hub logs per update)
METRICS = (
    "q_loss", "actor_loss", "alpha", "q1_mean",
    "logp_mean", "target_q_mean", "reward_mean", "entropy_term",
)
N_METRICS = len(METRICS)


# ---------------------------------------------------------------- unflatten

def view(flat, segments, prefix):
    """Slice the named MLP (w0,b0,w1,b1,w2,b2) out of a flat vector."""
    names = [prefix + n for n in ("w0", "b0", "w1", "b1", "w2", "b2")]
    by_name = {seg.name: seg for seg in segments}
    out = []
    for n in names:
        seg = by_name[n]
        out.append(flat[seg.offset: seg.offset + seg.size].reshape(seg.shape))
    return out


def scalar_view(flat, segments, name):
    for seg in segments:
        if seg.name == name:
            return flat[seg.offset]
    raise KeyError(name)


def mlp(x, layers, final_act="none"):
    w0, b0, w1, b1, w2, b2 = layers
    h = fused_linear(x, w0, b0, "relu")
    h = fused_linear(h, w1, b1, "relu")
    return fused_linear(h, w2, b2, final_act)


# ---------------------------------------------------------------- networks

def actor_forward(lay: Layout, actor_flat, s):
    """Returns (mu, log_std) for SAC or (mu, None) for TD3."""
    layers = view(actor_flat, lay.actor_segments, "actor/")
    out = mlp(s, layers)
    if lay.algo == "sac":
        mu, log_std = jnp.split(out, 2, axis=-1)
        return mu, jnp.clip(log_std, ref.LOG_STD_MIN, ref.LOG_STD_MAX)
    return out, None


def q_forward(lay: Layout, critic_flat, s, a):
    sa = jnp.concatenate([s, a], axis=-1)
    q1 = mlp(sa, view(critic_flat, lay.critic_segments, "q1/"))
    q2 = mlp(sa, view(critic_flat, lay.critic_segments, "q2/"))
    return q1[:, 0], q2[:, 0]


def policy_act(lay: Layout, actor_flat, s, noise, deterministic):
    """Inference-path action (uses the fused gaussian_head kernel).

    ``deterministic``: f32 scalar 0/1 — 1 zeroes the exploration noise
    (used by the paper's test/visualization processes).
    """
    mu, log_std = actor_forward(lay, actor_flat, s)
    if lay.algo == "td3":
        return jnp.tanh(mu) + noise * (1.0 - deterministic)
    a, _ = gaussian_head(mu, log_std, noise * (1.0 - deterministic))
    return a


# ---------------------------------------------------------------- SAC losses

def _sac_losses(lay: Layout, actor_flat, critic_flat, targets, batch, hyper):
    """Shared by full/actor/critic steps. Gradient isolation follows the
    paper's Fig. 3: the actor loss sees stop_gradient critic params; the
    critic TD target sees stop_gradient actor outputs."""
    s, a, r, d, s2, noise1, noise2 = batch
    gamma, tau = hyper[1], hyper[2]
    target_entropy, reward_scale = hyper[3], hyper[4]
    log_alpha = scalar_view(actor_flat, lay.actor_segments, "actor/log_alpha")
    alpha = jnp.exp(log_alpha)

    # --- critic loss (TD with double-Q and entropy bonus)
    mu2, ls2 = actor_forward(lay, jax.lax.stop_gradient(actor_flat), s2)
    a2, logp2 = ref.gaussian_head(mu2, ls2, noise2)
    q1t, q2t = q_forward(lay, targets, s2, a2)
    target_q = r * reward_scale + gamma * (1.0 - d) * (
        jnp.minimum(q1t, q2t) - jax.lax.stop_gradient(alpha) * logp2
    )
    target_q = jax.lax.stop_gradient(target_q)
    q1, q2 = q_forward(lay, critic_flat, s, a)
    q_loss = jnp.mean((q1 - target_q) ** 2) + jnp.mean((q2 - target_q) ** 2)

    # --- actor loss (critic frozen)
    mu1, ls1 = actor_forward(lay, actor_flat, s)
    a1, logp1 = ref.gaussian_head(mu1, ls1, noise1)
    q1pi, q2pi = q_forward(lay, jax.lax.stop_gradient(critic_flat), s, a1)
    actor_loss = jnp.mean(
        jax.lax.stop_gradient(alpha) * logp1 - jnp.minimum(q1pi, q2pi)
    )

    # --- temperature loss
    alpha_loss = -jnp.mean(
        log_alpha * (jax.lax.stop_gradient(logp1) + target_entropy)
    )

    metrics = jnp.stack([
        q_loss, actor_loss, alpha, jnp.mean(q1),
        jnp.mean(logp1), jnp.mean(target_q), jnp.mean(r),
        -jnp.mean(logp1),
    ])
    return q_loss, actor_loss, alpha_loss, metrics


def sac_full_step(lay: Layout):
    """Single-device SAC update: returns f(params, targets, m, v, step,
    s, a, r, d, s2, noise1, noise2, hyper) -> (params', targets', m', v',
    metrics)."""
    pa = lay.actor_size

    def step_fn(params, targets, m, v, step, s, a, r, d, s2, n1, n2, hyper):
        batch = (s, a, r, d, s2, n1, n2)

        def total_loss(p):
            ql, al, tl, metrics = _sac_losses(lay, p[:pa], p[pa:], targets, batch, hyper)
            return ql + al + tl, metrics

        (_, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(params)
        params2, m2, v2 = adam_update(params, grads, m, v, hyper[0], step)
        targets2 = polyak(params2[pa:], targets, hyper[2])
        return params2, targets2, m2, v2, metrics

    return step_fn


def sac_critic_step(lay: Layout):
    """Device-1 ("GPU1") half of the model-parallel update: critic + targets.
    Receives r,d (paper: allocated only to the critic device) plus s,a,s2."""

    def step_fn(actor_params, critic_params, targets, m, v, step,
                s, a, r, d, s2, n2, hyper):
        gamma, reward_scale = hyper[1], hyper[4]
        log_alpha = scalar_view(actor_params, lay.actor_segments, "actor/log_alpha")
        alpha = jnp.exp(log_alpha)

        mu2, ls2 = actor_forward(lay, actor_params, s2)
        a2, logp2 = ref.gaussian_head(mu2, ls2, n2)
        q1t, q2t = q_forward(lay, targets, s2, a2)
        target_q = r * reward_scale + gamma * (1.0 - d) * (
            jnp.minimum(q1t, q2t) - alpha * logp2
        )

        def q_loss_fn(cp):
            q1, q2 = q_forward(lay, cp, s, a)
            loss = jnp.mean((q1 - target_q) ** 2) + jnp.mean((q2 - target_q) ** 2)
            return loss, jnp.mean(q1)

        (q_loss, q1_mean), grads = jax.value_and_grad(q_loss_fn, has_aux=True)(critic_params)
        critic2, m2, v2 = adam_update(critic_params, grads, m, v, hyper[0], step)
        targets2 = polyak(critic2, targets, hyper[2])
        metrics = jnp.stack([
            q_loss, jnp.float32(0.0), alpha, q1_mean,
            jnp.mean(logp2), jnp.mean(target_q), jnp.mean(r), -jnp.mean(logp2),
        ])
        return critic2, targets2, m2, v2, metrics

    return step_fn


def sac_actor_step(lay: Layout):
    """Device-0 ("GPU0") half of the model-parallel update: policy + alpha.
    Uses the freshest critic params shipped over (frozen here)."""

    def step_fn(actor_params, critic_params, m, v, step, s, n1, hyper):
        target_entropy = hyper[3]

        def loss_fn(ap):
            log_alpha = scalar_view(ap, lay.actor_segments, "actor/log_alpha")
            alpha = jnp.exp(log_alpha)
            mu1, ls1 = actor_forward(lay, ap, s)
            a1, logp1 = ref.gaussian_head(mu1, ls1, n1)
            q1pi, q2pi = q_forward(lay, critic_params, s, a1)
            actor_loss = jnp.mean(
                jax.lax.stop_gradient(alpha) * logp1 - jnp.minimum(q1pi, q2pi)
            )
            alpha_loss = -jnp.mean(
                log_alpha * (jax.lax.stop_gradient(logp1) + target_entropy)
            )
            aux = (actor_loss, alpha, jnp.mean(logp1), jnp.mean(q1pi))
            return actor_loss + alpha_loss, aux

        (_, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(actor_params)
        actor2, m2, v2 = adam_update(actor_params, grads, m, v, hyper[0], step)
        actor_loss, alpha, logp_mean, q_mean = aux
        metrics = jnp.stack([
            jnp.float32(0.0), actor_loss, alpha, q_mean,
            logp_mean, jnp.float32(0.0), jnp.float32(0.0), -logp_mean,
        ])
        return actor2, m2, v2, metrics

    return step_fn


# ---------------------------------------------------------------- TD3

def td3_full_step(lay: Layout):
    """TD3 update (paper §4.2.4 algorithm robustness). ``update_actor`` is a
    0/1 f32 scalar implementing the delayed policy update: actor/target
    changes are multiplied by it so one artifact serves both phases.

    noise2 here is the *target policy smoothing* noise (clipped outside by
    hyper[5] = policy_noise scale)."""
    pa = lay.actor_size

    def step_fn(params, targets, m, v, step, s, a, r, d, s2, n2, update_actor, hyper):
        gamma, tau = hyper[1], hyper[2]
        reward_scale, policy_noise = hyper[4], hyper[5]

        def total_loss(p):
            ap, cp = p[:pa], p[pa:]
            # critic loss with target policy smoothing
            mu2, _ = actor_forward(lay, jax.lax.stop_gradient(ap), s2)
            eps = jnp.clip(n2 * policy_noise, -0.5, 0.5)
            a2 = jnp.clip(jnp.tanh(mu2) + eps, -1.0, 1.0)
            q1t, q2t = q_forward(lay, targets, s2, a2)
            target_q = jax.lax.stop_gradient(
                r * reward_scale + gamma * (1.0 - d) * jnp.minimum(q1t, q2t)
            )
            q1, q2 = q_forward(lay, cp, s, a)
            q_loss = jnp.mean((q1 - target_q) ** 2) + jnp.mean((q2 - target_q) ** 2)
            # actor loss (delayed, critic frozen)
            mu1, _ = actor_forward(lay, ap, s)
            a1 = jnp.tanh(mu1)
            q1pi, _ = q_forward(lay, jax.lax.stop_gradient(cp), s, a1)
            actor_loss = -jnp.mean(q1pi)
            metrics = jnp.stack([
                q_loss, actor_loss, jnp.float32(0.0), jnp.mean(q1),
                jnp.float32(0.0), jnp.mean(target_q), jnp.mean(r), jnp.float32(0.0),
            ])
            return q_loss + update_actor * actor_loss, metrics

        (_, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(params)
        params2, m2, v2 = adam_update(params, grads, m, v, hyper[0], step)
        # delayed target update: interpolate only when the actor updated
        tau_eff = hyper[2] * update_actor
        targets2 = polyak(params2[pa:], targets, tau_eff)
        return params2, targets2, m2, v2, metrics

    return step_fn
