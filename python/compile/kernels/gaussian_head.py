"""L1 kernel: fused tanh-squashed gaussian policy head.

Computes the action AND its log-probability in one VMEM-resident pass:

    u    = mu + exp(clip(log_std)) * noise
    a    = tanh(u)
    logp = sum_j [ -0.5*noise^2 - log_std - 0.5*log(2pi) - log(1 - a^2 + eps) ]

Used in the ``policy_act`` artifact (inference path — no gradient needed;
the differentiable training path uses the jnp oracle ``ref.gaussian_head``
whose numerics these kernels are tested to match exactly).

Grid is over batch rows only; the action dim (1..17 for our envs) stays
whole inside the block, so the row-sum reduction for logp happens entirely
in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .fused_linear import pick_block, BM_PREF


def _head_kernel(mu_ref, ls_ref, n_ref, a_ref, lp_ref):
    mu = mu_ref[...]
    ls = jnp.clip(ls_ref[...], ref.LOG_STD_MIN, ref.LOG_STD_MAX)
    noise = n_ref[...]
    u = mu + jnp.exp(ls) * noise
    a = jnp.tanh(u)
    half_log_2pi = 0.5 * jnp.log(2.0 * jnp.pi).astype(jnp.float32)
    per = -0.5 * noise * noise - ls - half_log_2pi - jnp.log(1.0 - a * a + ref.SQUASH_EPS)
    a_ref[...] = a
    lp_ref[...] = jnp.sum(per, axis=-1)


def gaussian_head(mu, log_std, noise):
    """Fused squash + log-prob. Returns (a [B,A], logp [B])."""
    bsz, adim = mu.shape
    assert log_std.shape == mu.shape and noise.shape == mu.shape
    bm = pick_block(bsz, BM_PREF)
    mat = pl.BlockSpec((bm, adim), lambda i: (i, 0))
    return pl.pallas_call(
        _head_kernel,
        grid=(bsz // bm,),
        in_specs=[mat, mat, mat],
        out_specs=[mat, pl.BlockSpec((bm,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, adim), jnp.float32),
            jax.ShapeDtypeStruct((bsz,), jnp.float32),
        ],
        interpret=True,
    )(mu, log_std, noise)
