"""L1 elementwise kernels over the flat parameter vector: fused Adam and
Polyak soft-update.

Both run on ``CHUNK``-divisible flat vectors (``layout.py`` pads every
segment), grid = P / CHUNK, one VMEM-resident block per step. Fusing the
whole optimizer update into one kernel means each of p/g/m/v makes exactly
one HBM->VMEM pass per step instead of the ~8 passes an unfused jnp chain
would make — this matters because at batch-size-8192 Spreeze's update rate is
bounded by optimizer bandwidth once the matmuls are tiled well.

Scalar hyperparameters travel as a tiny broadcast vector (same block for
every grid step) rather than being baked into the HLO, so one artifact serves
any (lr, tau, step-count) the Rust coordinator chooses at runtime.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..layout import CHUNK

ADAM_BETA1 = 0.9
ADAM_BETA2 = 0.999
ADAM_EPS = 1e-8


def _adam_kernel(h_ref, p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref):
    # h = [lr, beta1, beta2, c1, c2, eps]; c_i = 1 / (1 - beta_i^t)
    h = h_ref[...]
    lr, b1, b2, c1, c2, eps = h[0], h[1], h[2], h[3], h[4], h[5]
    g = g_ref[...]
    m2 = b1 * m_ref[...] + (1.0 - b1) * g
    v2 = b2 * v_ref[...] + (1.0 - b2) * g * g
    po_ref[...] = p_ref[...] - lr * (m2 * c1) / (jnp.sqrt(v2 * c2) + eps)
    mo_ref[...] = m2
    vo_ref[...] = v2


def adam_update(p, g, m, v, lr, t, beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS):
    """Fused Adam over a flat CHUNK-padded vector.

    ``t`` (step count, >= 1) and ``lr`` may be traced scalars — bias
    correction is folded into two scalars outside the kernel.
    """
    (n,) = p.shape
    assert n % CHUNK == 0, f"flat vector not CHUNK-padded: {n}"
    t = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    c1 = 1.0 / (1.0 - jnp.power(beta1, t))
    c2 = 1.0 / (1.0 - jnp.power(beta2, t))
    h = jnp.stack([
        jnp.float32(lr) if not hasattr(lr, "astype") else lr.astype(jnp.float32),
        jnp.float32(beta1), jnp.float32(beta2), c1, c2, jnp.float32(eps),
    ])
    grid = (n // CHUNK,)
    vec = pl.BlockSpec((CHUNK,), lambda i: (i,))
    scl = pl.BlockSpec((6,), lambda i: (0,))
    p2, m2, v2 = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[scl, vec, vec, vec, vec],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(h, p, g, m, v)
    return p2, m2, v2


def _polyak_kernel(h_ref, p_ref, t_ref, o_ref):
    tau = h_ref[...][0]
    o_ref[...] = tau * p_ref[...] + (1.0 - tau) * t_ref[...]


def polyak(p, t, tau):
    """Fused soft target update t' = tau*p + (1-tau)*t over a flat vector."""
    (n,) = p.shape
    assert p.shape == t.shape and n % CHUNK == 0
    h = jnp.stack([jnp.float32(tau) if not hasattr(tau, "astype") else tau.astype(jnp.float32)])
    vec = pl.BlockSpec((CHUNK,), lambda i: (i,))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _polyak_kernel,
        grid=(n // CHUNK,),
        in_specs=[scl, vec, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(h, p, t)
