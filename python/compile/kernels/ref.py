"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels (run in
interpret mode) match these to tight tolerances — forward AND backward where
the kernel carries a custom_vjp.
"""

import jax.numpy as jnp

LOG_STD_MIN = -5.0
LOG_STD_MAX = 2.0
SQUASH_EPS = 1e-6
_HALF_LOG_2PI = 0.5 * jnp.log(2.0 * jnp.pi)


def apply_act(y, act: str):
    if act == "none":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "tanh":
        return jnp.tanh(y)
    raise ValueError(f"unknown activation {act!r}")


def fused_linear(x, w, b, act: str = "none"):
    """y = act(x @ w + b); the network-update hot spot."""
    return apply_act(jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :], act)


def matmul(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def adam_update(p, g, m, v, lr, beta1, beta2, eps, t):
    """Standard Adam with bias correction at integer step t (t >= 1)."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    p2 = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p2, m2, v2


def polyak(p, t, tau):
    """Soft target update t' = tau * p + (1 - tau) * t."""
    return tau * p + (1.0 - tau) * t


def gaussian_head(mu, log_std, noise):
    """Tanh-squashed gaussian policy head.

    a = tanh(mu + exp(log_std) * noise)
    logp = sum_j [ -0.5*noise_j^2 - log_std_j - 0.5*log(2pi)
                   - log(1 - a_j^2 + eps) ]
    Returns (a [B,A], logp [B]).
    """
    log_std = jnp.clip(log_std, LOG_STD_MIN, LOG_STD_MAX)
    u = mu + jnp.exp(log_std) * noise
    a = jnp.tanh(u)
    per = -0.5 * noise * noise - log_std - _HALF_LOG_2PI - jnp.log(1.0 - a * a + SQUASH_EPS)
    return a, jnp.sum(per, axis=-1)
