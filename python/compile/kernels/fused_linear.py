"""L1 hot-spot kernel: fused ``act(x @ w + b)`` with a custom VJP whose
backward passes are Pallas matmul kernels too.

TPU adaptation of the paper's GPU large-batch update (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks + shared memory we tile
the ``[B,K] x [K,N]`` product into MXU-shaped blocks staged through VMEM by
``BlockSpec``; bias-add and activation are fused into the epilogue so the
pre-activation tensor never round-trips to HBM; accumulation is f32.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
the Rust runtime executes. Block shapes are still chosen as they would be on
real TPU hardware; §Perf in EXPERIMENTS.md carries the VMEM/MXU analysis.

Block-shape policy: dims < 128 are taken whole (RL nets have tiny obs/act
dims); dims >= 128 here are multiples of 128 by construction (hidden sizes
64/256, batch sizes powers of two), so every grid divides exactly and no
masking is needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Preferred tile edges. Lane dim stays MXU-friendly (multiples of 128); the
# batch (sublane) dim uses much taller tiles: VMEM comfortably holds
# bm*K + K*bn + bm*bn f32 at (1024, 256, 256) = ~2.3 MB << 16 MB, and taller
# tiles shrink the grid loop, which dominates both the interpret-mode HLO
# (sequential while-loop iterations) and real-TPU grid dispatch.
# §Perf iteration 1 in EXPERIMENTS.md: (128,128) -> (1024,256) tiles.
BM_PREF = 2048
BN_PREF = 256


def pick_block(dim: int, pref: int = BN_PREF) -> int:
    """Whole dim when small, else the largest preferred tile that divides."""
    if dim < pref:
        return dim
    for cand in (pref, 1024, 512, 256, 128, 64, 32, 16, 8):
        if cand <= pref and dim % cand == 0:
            return cand
    return 1


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    x = x_ref[...]  # (bm, K) in VMEM
    w = w_ref[...]  # (K, bn) in VMEM
    b = b_ref[...]  # (bn,)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    o_ref[...] = ref.apply_act(y, act)


def _linear_impl(x, w, b, act: str):
    bsz, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm, bn = pick_block(bsz, BM_PREF), pick_block(n, BN_PREF)
    grid = (bsz // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_linear_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul(a, b):
    """Plain Pallas tiled matmul — used by the fused_linear backward pass."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn = pick_block(m, BM_PREF), pick_block(n, BN_PREF)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act: str = "none"):
    """act(x @ w + b). Differentiable via Pallas backward kernels."""
    return _linear_impl(x, w, b, act)


def _fused_linear_fwd(x, w, b, act: str):
    y = _linear_impl(x, w, b, act)
    # For relu/tanh the activation derivative is recoverable from y itself,
    # so we never materialize the pre-activation.
    return y, (x, w, y)


def _act_bwd(dy, y, act: str):
    if act == "none":
        return dy
    if act == "relu":
        return dy * (y > 0.0).astype(dy.dtype)
    if act == "tanh":
        return dy * (1.0 - y * y)
    raise ValueError(act)


def _fused_linear_bwd(act, res, dy):
    x, w, y = res
    dpre = _act_bwd(dy, y, act)
    dx = matmul(dpre, w.T)
    dw = matmul(x.T, dpre)
    db = jnp.sum(dpre, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
