"""Flat parameter layout shared between the JAX compile path and the Rust runtime.

Every network parameter lives in ONE flat f32 vector. The layout (segment
name, shape, offset) is computed here, embedded into ``artifacts/manifest.json``
by ``aot.py``, and parsed by ``rust/src/nn/layout.rs`` — so the Rust sampler's
native MLP forward and the JAX update artifacts agree on byte-for-byte
parameter placement, and checkpoints ("SSD weight transmission" in the paper)
are just the flat vector on disk.

Layout (SAC):
    actor segment : actor MLP (obs -> h -> h -> 2*act) + log_alpha + pad
    critic segment: q1 MLP + q2 MLP (obs+act -> h -> h -> 1)   + pad
    full params   : concat(actor_seg, critic_seg)
    targets       : critic segment structure (q1t + q2t)       + pad

Layout (TD3): actor outputs ``act`` (deterministic), no log_alpha.

Segments are padded to CHUNK so the fused Adam/Polyak Pallas kernels get an
exactly-divisible grid.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

# Elementwise-kernel block size; every flat segment is padded to a multiple.
# 16384 f32 = 64 KiB per operand block (Adam streams 4 of them = 256 KiB of
# VMEM) — big enough that the grid loop stops dominating the optimizer
# kernels (§Perf iteration 1), small enough to stay far inside VMEM.
CHUNK = 16384

ENV_PRESETS = {
    # name: (obs_dim, act_dim, hidden)
    "pendulum": (3, 1, 64),
    "walker": (22, 6, 256),
    "cheetah": (26, 6, 256),
    "ant": (28, 8, 256),
    "humanoid": (44, 17, 256),
    "humanoid_flagrun": (46, 17, 256),
}


@dataclasses.dataclass
class Segment:
    name: str
    shape: Tuple[int, ...]
    offset: int  # element offset within its flat vector

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def to_json(self):
        return {"name": self.name, "shape": list(self.shape), "offset": self.offset}


def mlp_shapes(in_dim: int, hidden: int, out_dim: int) -> List[Tuple[str, Tuple[int, ...]]]:
    """Two-hidden-layer MLP: in -> h -> h -> out (weights stored (in, out))."""
    return [
        ("w0", (in_dim, hidden)),
        ("b0", (hidden,)),
        ("w1", (hidden, hidden)),
        ("b1", (hidden,)),
        ("w2", (hidden, out_dim)),
        ("b2", (out_dim,)),
    ]


def _pad_to_chunk(n: int) -> int:
    return ((n + CHUNK - 1) // CHUNK) * CHUNK


@dataclasses.dataclass
class Layout:
    """Full parameter/target layout for one (env, algo) pair."""

    env: str
    algo: str  # "sac" | "td3"
    obs_dim: int
    act_dim: int
    hidden: int
    actor_segments: List[Segment]
    critic_segments: List[Segment]
    target_segments: List[Segment]
    actor_size: int  # padded
    critic_size: int  # padded
    target_size: int  # padded

    @property
    def param_size(self) -> int:
        return self.actor_size + self.critic_size

    def segment(self, name: str) -> Segment:
        for seg in self.actor_segments + self.critic_segments + self.target_segments:
            if seg.name == name:
                return seg
        raise KeyError(name)

    def to_json(self):
        return {
            "env": self.env,
            "algo": self.algo,
            "obs_dim": self.obs_dim,
            "act_dim": self.act_dim,
            "hidden": self.hidden,
            "actor_size": self.actor_size,
            "critic_size": self.critic_size,
            "target_size": self.target_size,
            "param_size": self.param_size,
            "chunk": CHUNK,
            "actor_segments": [s.to_json() for s in self.actor_segments],
            "critic_segments": [s.to_json() for s in self.critic_segments],
            "target_segments": [s.to_json() for s in self.target_segments],
        }


def build_layout(env: str, algo: str = "sac") -> Layout:
    obs_dim, act_dim, hidden = ENV_PRESETS[env]
    actor_out = 2 * act_dim if algo == "sac" else act_dim

    actor_segments: List[Segment] = []
    off = 0
    for name, shape in mlp_shapes(obs_dim, hidden, actor_out):
        actor_segments.append(Segment(f"actor/{name}", shape, off))
        off += actor_segments[-1].size
    if algo == "sac":
        actor_segments.append(Segment("actor/log_alpha", (1,), off))
        off += 1
    actor_size = _pad_to_chunk(off)

    critic_segments: List[Segment] = []
    off = 0
    for q in ("q1", "q2"):
        for name, shape in mlp_shapes(obs_dim + act_dim, hidden, 1):
            critic_segments.append(Segment(f"{q}/{name}", shape, off))
            off += critic_segments[-1].size
    critic_size = _pad_to_chunk(off)

    target_segments = [
        Segment(f"target_{s.name}", s.shape, s.offset) for s in critic_segments
    ]
    target_size = critic_size

    return Layout(
        env=env,
        algo=algo,
        obs_dim=obs_dim,
        act_dim=act_dim,
        hidden=hidden,
        actor_segments=actor_segments,
        critic_segments=critic_segments,
        target_segments=target_segments,
        actor_size=actor_size,
        critic_size=critic_size,
        target_size=target_size,
    )
