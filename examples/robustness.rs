//! Fig. 8-style robustness demo: run the same walker training under three
//! simulated hardware profiles (desktop / server / laptop) and two
//! algorithms (SAC / TD3), letting the adaptation controller pick (BS, SP)
//! per device — the paper's §4.2.4.
//!
//!     cargo run --release --example robustness -- [seconds-per-run]

use spreeze::config::{presets, Algo, HardwareProfile};
use spreeze::coordinator::Coordinator;
use spreeze::util::sysinfo;

fn main() -> anyhow::Result<()> {
    let secs: f64 = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(45.0);
    let cores = sysinfo::num_cpus();

    println!("== device robustness (walker, SAC, {secs:.0}s each) ==");
    for (label, core_frac, throttle) in
        [("desktop", 1.0, 1.0), ("server", 1.0, 1.0), ("laptop", 4.0 / cores as f64, 0.35)]
    {
        let mut cfg = presets::preset("walker");
        cfg.max_seconds = secs;
        cfg.target_return = None;
        cfg.hardware = HardwareProfile {
            cpu_cores: ((cores as f64 * core_frac).round() as usize).max(2),
            gpus: 1,
            gpu_throttle: throttle,
        };
        cfg.run_dir = format!("results/robustness_{label}");
        let s = Coordinator::new(cfg).run()?;
        println!(
            "  {label:8} adapted bs={:5} sp={:2}  upd_frame {:10.0}/s  final {:8.1}",
            s.batch_size, s.n_samplers, s.update_frame_hz, s.final_return
        );
    }

    println!("\n== algorithm robustness (walker, {secs:.0}s each) ==");
    for algo in [Algo::Sac, Algo::Td3] {
        let mut cfg = presets::preset("walker");
        cfg.algo = algo;
        cfg.max_seconds = secs;
        cfg.target_return = None;
        cfg.batch_size = 8192;
        cfg.adapt = false;
        cfg.run_dir = format!("results/robustness_{}", algo.name());
        let s = Coordinator::new(cfg).run()?;
        println!(
            "  {:8} upd {:6.1}/s  final {:8.1} (best {:8.1})",
            algo.name(),
            s.update_hz,
            s.final_return,
            s.best_return
        );
    }
    Ok(())
}
