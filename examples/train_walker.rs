//! Train SAC on the Walker2D-lite locomotion task with the full Spreeze
//! feature set: hyperparameter adaptation AND dual-executor "Actor-Critic"
//! model parallelism (paper §3.2.2 / Fig. 3).
//!
//!     cargo run --release --example train_walker -- [seconds] [--single]

use spreeze::config::presets;
use spreeze::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let secs: f64 = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(120.0);
    let single = args.iter().any(|a| a == "--single");

    let mut cfg = presets::preset("walker");
    cfg.seed = 0;
    cfg.max_seconds = secs;
    cfg.target_return = None;
    cfg.verbose = true;
    cfg.run_dir = "results/train_walker".into();
    if single {
        println!("single-executor mode (adaptation picks the batch size)\n");
    } else {
        println!("dual-executor Actor-Critic model parallelism (paper Fig. 3)\n");
        cfg.model_parallel = true;
        cfg.batch_size = 8192; // the split artifacts are compiled at 8192
        cfg.adapt = false;
    }
    let s = Coordinator::new(cfg).run()?;
    println!("\n=== walker summary ===");
    println!("mode               : {}", if single { "single" } else { "model-parallel" });
    println!("updates            : {} (bs {})", s.updates, s.batch_size);
    println!("sampling rate      : {:.0} Hz", s.sampling_hz);
    println!("update frame rate  : {:.0} Hz", s.update_frame_hz);
    println!("executor usage     : {:.0}%", s.gpu_usage * 100.0);
    println!("final eval return  : {:.1} (best {:.1})", s.final_return, s.best_return);
    println!("curve: results/train_walker/curve.csv");
    Ok(())
}
