//! Quickstart: train SAC on Pendulum-v0 until solved (eval return >= -200)
//! with the full Spreeze topology — async sampler pool, shared-memory
//! replay, PJRT-compiled update artifacts, SSD weight sync, eval worker.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Solves in well under two minutes on a modest desktop; the run is logged
//! in EXPERIMENTS.md (E2E validation).

use spreeze::config::presets;
use spreeze::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let mut cfg = presets::preset("pendulum");
    cfg.seed = 0;
    cfg.max_seconds = 300.0;
    cfg.target_return = Some(-200.0);
    cfg.verbose = true;
    cfg.run_dir = "results/quickstart".into();
    println!("training SAC on pendulum until eval return >= -200 ...\n");
    let s = Coordinator::new(cfg).run()?;
    println!("\n=== quickstart summary ===");
    println!("updates            : {}", s.updates);
    println!("env frames sampled : {}", s.sampled_frames);
    println!("sampling rate      : {:.0} Hz", s.sampling_hz);
    println!("update frame rate  : {:.0} Hz", s.update_frame_hz);
    println!("final eval return  : {:.1}", s.final_return);
    match s.solved_s {
        Some(t) => println!("SOLVED in {t:.1}s wall clock"),
        None => println!("not solved within budget (final {:.1})", s.final_return),
    }
    println!("curve: results/quickstart/curve.csv");
    Ok(())
}
