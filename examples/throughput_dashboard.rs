//! Live Table-2-style throughput dashboard: runs Spreeze on any env for a
//! fixed window, printing one metrics row per second (CPU%, sampling Hz,
//! executor%, update frame rate, update frequency, transmission loss), then
//! a Table 2/3-format summary line.
//!
//!     cargo run --release --example throughput_dashboard -- [env] [seconds]

use spreeze::config::presets;
use spreeze::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let env = args.first().cloned().unwrap_or_else(|| "walker".to_string());
    let secs: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(30.0);

    let mut cfg = presets::preset(&env);
    cfg.seed = 0;
    cfg.max_seconds = secs;
    cfg.target_return = None;
    cfg.verbose = true; // per-second rows
    cfg.run_dir = format!("results/dashboard_{env}");
    println!("spreeze throughput dashboard — env={env}, {secs:.0}s\n");
    let s = Coordinator::new(cfg).run()?;

    println!("\n{:-^78}", " steady-state (Table 2 row format) ");
    println!(
        "{:<14} {:>6} {:>12} {:>6} {:>14} {:>10} {:>7}",
        "framework", "CPU%", "Sample Hz", "GPU%", "UpdFrame Hz", "Upd Hz", "Loss%"
    );
    println!(
        "{:<14} {:>5.0}% {:>12.0} {:>5.0}% {:>14.3e} {:>10.1} {:>6.1}%",
        "spreeze",
        s.cpu_usage * 100.0,
        s.sampling_hz,
        s.gpu_usage * 100.0,
        s.update_frame_hz,
        s.update_hz,
        s.loss_fraction * 100.0
    );
    println!("metrics timeline: results/dashboard_{env}/metrics.csv");
    Ok(())
}
